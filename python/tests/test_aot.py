"""Artifact-format tests: AFWB/AFED binary layouts + manifest schema.

These pin the python→rust interchange contract (the rust side has the
mirrored parsers in rust/src/model/weights.rs and rust/src/dataset/).
"""

import json
import os
import struct

import numpy as np
import pytest

from compile import aot

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_weights_bin_roundtrip(tmp_path):
    path = tmp_path / "w.bin"
    t1 = np.arange(-6, 6, dtype=np.int32).reshape(2, 3, 2)
    t2 = np.array([7, -8, 9], dtype=np.int32)
    aot.write_weights_bin(str(path), [t1, t2])
    b = path.read_bytes()
    assert b[:4] == b"AFWB"
    version, count = struct.unpack("<II", b[4:12])
    assert (version, count) == (1, 2)
    off = 12
    for expected in (t1, t2):
        ndim = struct.unpack("<I", b[off : off + 4])[0]
        off += 4
        dims = struct.unpack(f"<{ndim}I", b[off : off + 4 * ndim])
        off += 4 * ndim
        assert dims == expected.shape
        n = int(np.prod(dims))
        got = np.frombuffer(b[off : off + 4 * n], dtype="<i4").reshape(dims)
        off += 4 * n
        np.testing.assert_array_equal(got, expected)
    assert off == len(b), "no trailing bytes"


def test_eval_bin_roundtrip(tmp_path):
    path = tmp_path / "e.bin"
    images = np.random.default_rng(0).random((5, 4, 4, 3)).astype(np.float32)
    labels = np.arange(5, dtype=np.int32)
    aot.write_eval_bin(str(path), images, labels)
    b = path.read_bytes()
    assert b[:4] == b"AFED"
    version, n, h, w, c = struct.unpack("<IIIII", b[4:24])
    assert (version, n, h, w, c) == (1, 5, 4, 4, 3)
    img = np.frombuffer(b[24 : 24 + n * h * w * c * 4], dtype="<f4").reshape(5, 4, 4, 3)
    np.testing.assert_array_equal(img, images)
    lbl = np.frombuffer(b[24 + n * h * w * c * 4 :], dtype="<i4")
    np.testing.assert_array_equal(lbl, labels)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "index.json")),
    reason="artifacts not built",
)
def test_built_manifests_schema():
    index = json.load(open(os.path.join(ARTIFACTS, "index.json")))
    assert set(index["models"]) == {"alexnet", "squeezenet", "resnet18"}
    for model in index["models"]:
        man = json.load(open(os.path.join(ARTIFACTS, f"{model}_manifest.json")))
        for key in (
            "model",
            "num_units",
            "precision",
            "faulty_bits",
            "batch",
            "hlo",
            "weights",
            "clean_acc_quant",
            "weight_scale",
            "units",
            "weight_tensors",
            "act_scales",
        ):
            assert key in man, f"{model}: missing {key}"
        assert len(man["units"]) == man["num_units"]
        # activation chain: unit i out_bytes == unit i+1 in_bytes
        for a, b in zip(man["units"], man["units"][1:]):
            assert a["out_bytes"] == b["in_bytes"]
        # all weight tensors reference real units and share the global scale
        unit_names = {u["name"] for u in man["units"]}
        for wt in man["weight_tensors"]:
            assert wt["unit"] in unit_names
            assert wt["scale"] == man["weight_scale"]
        # the HLO must not contain elided constants (the silent-zeros bug)
        hlo = open(os.path.join(ARTIFACTS, man["hlo"])).read()
        assert "constant({...})" not in hlo, f"{model}: elided constants in HLO"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "index.json")),
    reason="artifacts not built",
)
def test_built_models_trained_above_chance():
    index = json.load(open(os.path.join(ARTIFACTS, "index.json")))
    for model, acc in index["clean_acc"].items():
        assert acc > 0.7, f"{model} clean quantized acc {acc}"
