"""Quantization-scheme tests: BN fold correctness, pow2 scales, PTQ fidelity."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import layers as ly, models as M, quantize as Q


def test_fold_bn_equivalence():
    """conv+BN(eval) == conv with folded weights, to numerical tolerance."""
    key = jax.random.key(0)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (2, 8, 8, 3), jnp.float32)
    w = jax.random.normal(ks[1], (3, 3, 3, 5), jnp.float32) * 0.2
    b = jax.random.normal(ks[2], (5,), jnp.float32) * 0.1
    gamma = jax.random.uniform(ks[3], (5,), jnp.float32, 0.5, 1.5)
    beta = jax.random.normal(ks[4], (5,), jnp.float32) * 0.1
    mean = jax.random.normal(ks[5], (5,), jnp.float32) * 0.1
    var = jnp.abs(jax.random.normal(ks[5], (5,), jnp.float32)) + 0.5

    y_bn = ly.batchnorm_eval(ly.conv2d(x, w, 1, 1) + b, gamma, beta, mean, var)
    wf, bf = ly.fold_bn(w, b, gamma, beta, mean, var)
    y_fold = ly.conv2d(x, wf, 1, 1) + bf
    np.testing.assert_allclose(np.asarray(y_bn), np.asarray(y_fold), rtol=1e-4, atol=1e-5)


@settings(max_examples=50, deadline=None)
@given(max_abs=st.floats(1e-6, 1e4), precision=st.sampled_from([8, 16]))
def test_pow2_scale_properties(max_abs, precision):
    _, qmax = ly.quant_range(precision)
    s = Q.pow2_scale(max_abs, qmax)
    # power of two
    assert math.log2(s) == round(math.log2(s))
    # covers the range, and is the smallest such power
    assert s * qmax >= max_abs * 0.999999
    assert (s / 2) * qmax < max_abs * 1.000001 or s <= 2 ** -40


def test_quant_range():
    assert ly.quant_range(8) == (-128, 127)
    assert ly.quant_range(16) == (-32768, 32767)


@pytest.mark.parametrize("precision", [8, 16])
def test_quantize_model_global_scale(precision):
    mdef = M.alexnet_mini()
    params, state = M.init_params(mdef, seed=1)
    qparams, scale = Q.quantize_model(mdef, params, state, precision)
    # every tensor shares the global scale
    for u in mdef.units:
        for k, v in qparams[u.name].items():
            if k.endswith("scale"):
                assert v == scale
    # values in range
    _, qmax = ly.quant_range(precision)
    for u in mdef.units:
        for k, v in qparams[u.name].items():
            if k.endswith("wq"):
                assert int(jnp.max(jnp.abs(v))) <= qmax
                assert v.dtype == jnp.int32


def test_quantization_error_bounded():
    """Dequantized weights are within scale/2 of the folded f32 weights."""
    mdef = M.squeezenet_mini()
    params, state = M.init_params(mdef, seed=2)
    qparams, scale = Q.quantize_model(mdef, params, state, 8)
    folded = Q.fold_all(mdef, params, state)
    for (uname, prefix), (w, _) in folded.items():
        wq = qparams[uname][Q._prefixed(prefix, "wq")]
        err = np.abs(np.asarray(wq, np.float32) * scale - np.asarray(w))
        # clipping cannot occur (scale covers global max), so error <= s/2
        assert err.max() <= scale / 2 + 1e-7, (uname, prefix)


def test_weight_tensor_order_stable_and_complete():
    mdef = M.resnet18_mini()
    params, state = M.init_params(mdef, seed=3)
    qparams, _ = Q.quantize_model(mdef, params, state, 8)
    order = Q.weight_tensor_order(mdef, qparams)
    # 1 conv1 + blocks(2 or 3 convs) + 1 fc
    n_proj = sum(1 for u in mdef.units if "p_wq" in qparams[u.name])
    assert len(order) == 1 + 8 * 2 + n_proj + 1
    assert order == Q.weight_tensor_order(mdef, qparams)
    # units appear in model order
    unit_order = [u.name for u in mdef.units]
    seen = [u for (u, _) in order]
    assert sorted(range(len(seen)), key=lambda i: unit_order.index(seen[i])) == list(
        range(len(seen))
    )


def test_calibrate_act_scales_pow2_and_positive():
    mdef = M.alexnet_mini()
    params, state = M.init_params(mdef, seed=4)
    images = np.random.default_rng(0).uniform(0, 1, (16, 32, 32, 3)).astype(np.float32)
    scales = Q.calibrate_act_scales(mdef, params, state, images, 8)
    assert set(scales) == {u.name for u in mdef.units}
    for v in scales.values():
        assert v > 0
        assert math.log2(v) == round(math.log2(v))
