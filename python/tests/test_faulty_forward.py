"""Integration tests of the exported computation (model.faulty_forward):

the L2 graph must be (a) runnable for every model, (b) clean at zero rates,
(c) monotonically degraded by growing fault rates, (d) deterministic given
the PRNG key — the properties the L3 optimizer relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models as M, quantize as Q
from compile.model import make_export_fn
from compile.quantize import _prefixed

BATCH = 8


@pytest.fixture(scope="module", params=["alexnet", "squeezenet", "resnet18"])
def exported(request):
    mdef = M.MODELS[request.param]()
    params, state = M.init_params(mdef, seed=11)
    qparams, _ = Q.quantize_model(mdef, params, state, 8)
    rng = np.random.default_rng(1)
    images = rng.uniform(0, 1, (BATCH, 32, 32, 3)).astype(np.float32)
    act_scales = Q.calibrate_act_scales(mdef, params, state, images, 8)
    fn, order = make_export_fn(mdef, qparams, act_scales, bits=4, precision=8)
    wqs = [qparams[u][_prefixed(p, "wq")] for (u, p) in order]
    return mdef, jax.jit(fn), wqs, jnp.asarray(images)


def _run(exported, w_rates, a_rates, key=(1, 2)):
    mdef, fn, wqs, images = exported
    L = mdef.num_units
    wr = jnp.full((L,), w_rates, jnp.float32) if np.isscalar(w_rates) else w_rates
    ar = jnp.full((L,), a_rates, jnp.float32) if np.isscalar(a_rates) else a_rates
    (logits,) = fn(images, *wqs, wr, ar, jnp.asarray(key, jnp.uint32))
    return np.asarray(logits)


def test_output_shape_and_finite(exported):
    logits = _run(exported, 0.0, 0.0)
    assert logits.shape == (BATCH, 10)
    assert np.isfinite(logits).all()


def test_zero_rate_is_deterministic_wrt_key(exported):
    """With rates=0 the PRNG key must not influence the output."""
    a = _run(exported, 0.0, 0.0, key=(1, 2))
    b = _run(exported, 0.0, 0.0, key=(99, 100))
    np.testing.assert_array_equal(a, b)


def test_same_key_same_faults(exported):
    a = _run(exported, 0.3, 0.3, key=(5, 6))
    b = _run(exported, 0.3, 0.3, key=(5, 6))
    np.testing.assert_array_equal(a, b)


def test_different_key_different_faults(exported):
    a = _run(exported, 0.3, 0.3, key=(5, 6))
    b = _run(exported, 0.3, 0.3, key=(7, 8))
    assert not np.array_equal(a, b)


def test_faults_perturb_logits(exported):
    clean = _run(exported, 0.0, 0.0)
    faulty = _run(exported, 0.4, 0.4)
    assert np.abs(clean - faulty).max() > 1e-3


def test_perturbation_grows_with_rate(exported):
    clean = _run(exported, 0.0, 0.0)
    d_lo = np.abs(_run(exported, 0.05, 0.05) - clean).mean()
    d_hi = np.abs(_run(exported, 0.4, 0.4) - clean).mean()
    assert d_hi > d_lo


def test_per_unit_rate_vector_respected(exported):
    """Faulting only unit 0's weights must differ from faulting only the last."""
    mdef, fn, wqs, images = exported
    L = mdef.num_units
    z = jnp.zeros((L,), jnp.float32)
    a = _run(exported, z.at[0].set(0.4), 0.0)
    b = _run(exported, z.at[L - 1].set(0.4), 0.0)
    assert not np.array_equal(a, b)
