"""Synthetic dataset sanity: determinism, balance, value ranges, difficulty."""

import numpy as np

from compile import synthdata as S


def test_deterministic():
    a = S.make_dataset(64, seed=5)
    b = S.make_dataset(64, seed=5)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_seed_changes_data():
    a = S.make_dataset(64, seed=5)
    b = S.make_dataset(64, seed=6)
    assert not np.array_equal(a[0], b[0])


def test_class_balance():
    _, labels = S.make_dataset(200, seed=0)
    counts = np.bincount(labels, minlength=S.NUM_CLASSES)
    assert counts.min() == counts.max() == 20


def test_value_range_and_dtype():
    images, labels = S.make_dataset(32, seed=1)
    assert images.dtype == np.float32 and labels.dtype == np.int32
    assert images.shape == (32, 32, 32, 3)
    assert images.min() >= 0.0 and images.max() <= 1.0


def test_split_disjoint_streams():
    tr, ev = S.train_eval_split(32, 32, seed=9)
    # different RNG streams -> no identical images across the split
    assert not np.array_equal(tr[0][:32], ev[0][:32])


def test_every_class_renderable():
    rng = np.random.default_rng(0)
    for c in range(S.NUM_CLASSES):
        img = S.make_sample(c, rng)
        assert img.shape == S.IMG_SHAPE
        assert np.isfinite(img).all()


def test_intra_class_variability():
    """Augmentation: two samples of the same class must differ."""
    rng = np.random.default_rng(0)
    a = S.make_sample(0, rng)
    b = S.make_sample(0, rng)
    assert np.abs(a - b).mean() > 0.01
