# AFarePart repo tooling.
#
#   make check      build + tests + eval-engine perf gate (scripts/check.sh)
#   make artifacts  regenerate the compiled model artifacts (needs the
#                   python/JAX build-time stack; the rust binary only
#                   consumes the result)

.PHONY: check artifacts

check:
	bash scripts/check.sh

artifacts:
	python3 python/compile/aot.py
