# AFarePart repo tooling.
#
#   make check        build + tests + eval-engine perf gate (scripts/check.sh)
#   make chaos-smoke  chaos-enabled synthetic online run: must survive the
#                     default failure stack and be bitwise-deterministic
#   make artifacts    regenerate the compiled model artifacts (needs the
#                     python/JAX build-time stack; the rust binary only
#                     consumes the result)

.PHONY: check chaos-smoke artifacts

check:
	bash scripts/check.sh

chaos-smoke:
	bash scripts/chaos_smoke.sh

artifacts:
	python3 python/compile/aot.py
