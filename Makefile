# AFarePart repo tooling.
#
#   make check        build + tests + eval-engine perf gate (scripts/check.sh)
#   make chaos-smoke  chaos-enabled synthetic online run: must survive the
#                     default failure stack and be bitwise-deterministic
#   make trace-smoke  traced synthetic online run: the JSONL event trace
#                     must be schema-valid and bitwise repeat-deterministic
#   make campaign-smoke  3x2 synthetic campaign on the parallel cell
#                     scheduler: report bitwise identical at 1 vs 4 workers
#   make artifacts    regenerate the compiled model artifacts (needs the
#                     python/JAX build-time stack; the rust binary only
#                     consumes the result)

.PHONY: check chaos-smoke trace-smoke campaign-smoke artifacts

check:
	bash scripts/check.sh

chaos-smoke:
	bash scripts/chaos_smoke.sh

trace-smoke:
	bash scripts/trace_smoke.sh

campaign-smoke:
	bash scripts/campaign_smoke.sh

artifacts:
	python3 python/compile/aot.py
