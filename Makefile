# AFarePart repo tooling.
#
#   make check        build + tests + eval-engine perf gate (scripts/check.sh)
#   make chaos-smoke  chaos-enabled synthetic online run: must survive the
#                     default failure stack and be bitwise-deterministic
#   make trace-smoke  traced synthetic online run: the JSONL event trace
#                     must be schema-valid and bitwise repeat-deterministic
#   make artifacts    regenerate the compiled model artifacts (needs the
#                     python/JAX build-time stack; the rust binary only
#                     consumes the result)

.PHONY: check chaos-smoke trace-smoke artifacts

check:
	bash scripts/check.sh

chaos-smoke:
	bash scripts/chaos_smoke.sh

trace-smoke:
	bash scripts/trace_smoke.sh

artifacts:
	python3 python/compile/aot.py
