#!/usr/bin/env bash
# Trace-schema golden gate (`make trace-smoke`): a 60-tick synthetic
# online run with `--trace` must (a) produce a schema-valid JSONL trace
# (every line self-describing: schema version, strictly increasing seq,
# a kind; never a wall-clock field), (b) be bitwise repeat-deterministic
# — two identical invocations produce identical trace files — and
# (c) leave the report deterministic once the wall-clock latency
# summaries and the Prometheus snapshot (histogram sums are wall times)
# are stripped, and (d) post-process through `trace analyze` into a
# non-empty attribution report that is itself bitwise
# repeat-deterministic.
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=target/release/afarepart
if [ ! -x "$BIN" ]; then
    echo "== building $BIN =="
    cargo build --release
fi

TMP="$(mktemp -d)"
trap 'rm -rf "$TMP"' EXIT

cat > "$TMP/spec.json" <<'EOF'
{
  "model": "synthetic-L12",
  "online": {"ticks": 60, "recv_timeout_ms": 250, "lookahead": 3},
  "chaos": {"enabled": true}
}
EOF

echo "== trace-smoke: run A =="
"$BIN" online --spec "$TMP/spec.json" --trace "$TMP/a.jsonl" \
    --format json --out "$TMP/a.json"
echo "== trace-smoke: run B (same seed; trace must be identical) =="
"$BIN" online --spec "$TMP/spec.json" --trace "$TMP/b.jsonl" \
    --format json --out "$TMP/b.json"

echo "== trace-smoke: bitwise repeat determinism =="
cmp "$TMP/a.jsonl" "$TMP/b.jsonl" \
    || { echo "trace files differ across identical invocations"; exit 1; }
echo "  $(wc -l < "$TMP/a.jsonl") events, identical across repeats: OK"

if ! command -v python3 >/dev/null 2>&1; then
    echo "python3 unavailable; skipping schema validation"
    exit 0
fi

echo "== trace-smoke: schema validation =="
python3 - "$TMP/a.jsonl" <<'EOF'
import json
import sys

SCHEMA = 2
kinds = {}
with open(sys.argv[1]) as f:
    lines = [line.rstrip("\n") for line in f]

assert lines, "trace file is empty"
for i, line in enumerate(lines):
    event = json.loads(line)  # every line must be a standalone JSON object
    assert isinstance(event, dict), f"line {i} is not an object"
    assert event.get("schema") == SCHEMA, f"line {i}: schema {event.get('schema')!r}"
    assert event.get("seq") == i, f"line {i}: seq {event.get('seq')!r} (must equal line index)"
    kind = event.get("kind")
    assert isinstance(kind, str) and kind, f"line {i}: missing kind"
    kinds[kind] = kinds.get(kind, 0) + 1
    for key in event:
        assert not key.endswith("_ms") and "wall" not in key, (
            f"line {i}: wall-clock field {key!r} breaks trace determinism"
        )

assert lines and json.loads(lines[0])["kind"] == "trace_start", "missing trace_start header"
spans = {json.loads(l).get("span") for l in lines} - {None}
assert "online.tick" in spans, f"no online.tick spans in {sorted(spans)}"
assert kinds.get("span", 0) >= 60, "fewer span events than ticks"
print("  kinds:", ", ".join(f"{k}={n}" for k, n in sorted(kinds.items())))
print("  spans:", ", ".join(sorted(spans)))
print("  schema-valid, wall-clock-free: OK")
EOF

echo "== trace-smoke: report determinism (wall-clock + snapshot stripped) =="
python3 - "$TMP/a.json" "$TMP/b.json" <<'EOF'
import json
import sys

a, b = (json.load(open(p)) for p in sys.argv[1:3])
assert "telemetry" in a, "--trace must fold a Prometheus snapshot into the report"
assert "afare_serve_batches_total" in a["telemetry"], "snapshot missing serving counters"
# Wall-clock latency summaries and the snapshot (whose histogram sums are
# wall times) are the only nondeterministic fields.
for doc in (a, b):
    doc.pop("exec_mean_ms", None)
    doc.pop("exec_p95_ms", None)
    doc.pop("telemetry", None)
assert a == b, "traced run is not deterministic across identical invocations"
print("  deterministic across repeats: OK")
EOF

echo "== trace-smoke: offline analyzer (attribution report) =="
"$BIN" trace analyze "$TMP/a.jsonl" --format json --out "$TMP/ra1.json"
"$BIN" trace analyze "$TMP/a.jsonl" --format json --out "$TMP/ra2.json"
"$BIN" trace analyze "$TMP/b.jsonl" --format json --out "$TMP/rb.json"
cmp "$TMP/ra1.json" "$TMP/ra2.json" \
    || { echo "trace analyze is not repeat-deterministic"; exit 1; }
cmp "$TMP/ra1.json" "$TMP/rb.json" \
    || { echo "identical traces produced different analyzer reports"; exit 1; }
python3 - "$TMP/ra1.json" "$TMP/a.jsonl" <<'EOF'
import json
import sys

report = json.load(open(sys.argv[1]))
lines = sum(1 for _ in open(sys.argv[2]))
ev = report["events"]
assert ev["parsed"] == lines, f"analyzer parsed {ev['parsed']} of {lines} lines"
assert not ev["truncated_tail"] and ev["malformed"] == 0 and ev["seq_gaps"] == 0
assert ev["by_kind"].get("span", 0) >= 60, "analyzer lost span events"
attr = report["attribution"]
assert attr["injected_by_class"], "chaos run produced an empty attribution report"
assert report["spans"]["critical_path"], "empty critical path"
assert report["cache"]["batch_calls"] > 0, "no eval.batch rollup"
print("  attribution classes:", ", ".join(sorted(attr["injected_by_class"])))
print("  analyzer report non-empty + deterministic: OK")
EOF
echo "trace-smoke: OK"
