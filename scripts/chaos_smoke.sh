#!/usr/bin/env bash
# Chaos smoke gate (`make chaos-smoke`): a 120-tick synthetic online run
# with the default chaos stack enabled must (a) complete without panic,
# (b) report the resilience counters, and (c) be bitwise-deterministic —
# two identical invocations produce identical JSON once the wall-clock
# latency summaries are stripped.
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=target/release/afarepart
if [ ! -x "$BIN" ]; then
    echo "== building $BIN =="
    cargo build --release
fi

TMP="$(mktemp -d)"
trap 'rm -rf "$TMP"' EXIT

cat > "$TMP/spec.json" <<'EOF'
{
  "model": "synthetic-L12",
  "online": {"ticks": 120, "recv_timeout_ms": 250, "lookahead": 3},
  "chaos": {"enabled": true}
}
EOF

echo "== chaos-smoke: run A =="
"$BIN" online --spec "$TMP/spec.json" --format json --out "$TMP/a.json"
echo "== chaos-smoke: run B (same seed; must be identical) =="
"$BIN" online --spec "$TMP/spec.json" --format json --out "$TMP/b.json"

if ! command -v python3 >/dev/null 2>&1; then
    echo "python3 unavailable; skipping determinism diff"
    exit 0
fi
python3 - "$TMP/a.json" "$TMP/b.json" <<'EOF'
import json
import sys

a, b = (json.load(open(p)) for p in sys.argv[1:3])

assert a["ticks"] == 120, f"expected 120 ticks, got {a['ticks']}"
assert len(a["timeline"]) == 120, "timeline truncated"
for key in (
    "worker_respawns",
    "retries",
    "transient_errors",
    "timeouts",
    "degradations",
    "degraded_ticks",
    "degraded_intervals",
):
    assert key in a, f"missing resilience counter {key!r}"

events = sum(a[k] for k in ("worker_respawns", "retries", "transient_errors", "timeouts"))
print(
    f"  respawns={a['worker_respawns']} retries={a['retries']} "
    f"transients={a['transient_errors']} timeouts={a['timeouts']} "
    f"degraded_ticks={a['degraded_ticks']} intervals={a['degraded_intervals']}"
)
assert events > 0, "default chaos stack over 120 ticks injected nothing"

# Wall-clock latency summaries are the only nondeterministic fields.
for doc in (a, b):
    doc.pop("exec_mean_ms", None)
    doc.pop("exec_p95_ms", None)
assert a == b, "chaos run is not deterministic across identical invocations"
print("  deterministic across repeats: OK")
EOF
echo "chaos-smoke: OK"
