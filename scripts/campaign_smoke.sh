#!/usr/bin/env bash
# Parallel-campaign golden gate (`make campaign-smoke`): a 3×2 synthetic
# campaign (3 fault rates × 2 scenarios, no artifacts needed) must
# (a) run to completion on the parallel cell scheduler, (b) produce a
# report that is bitwise identical — modulo the one wall-clock field —
# across `--campaign-workers 1` and `--campaign-workers 4`, and
# (c) report a well-formed `cache_sharing` section whose invariants
# (`saved_backend_evals = private_misses - unique_keys`) hold.
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=target/release/afarepart
if [ ! -x "$BIN" ]; then
    echo "== building $BIN =="
    cargo build --release
fi

TMP="$(mktemp -d)"
trap 'rm -rf "$TMP"' EXIT

cat > "$TMP/spec.json" <<'EOF'
{
  "base": {"eval_threads": 1, "optimizer": {"pop_size": 8, "generations": 2}},
  "grid": {
    "models": ["synthetic-L6"],
    "fault_rates": [0.1, 0.2, 0.4],
    "scenarios": ["w", "iw"]
  }
}
EOF

echo "== campaign-smoke: serial run (1 worker) =="
"$BIN" campaign --spec "$TMP/spec.json" --campaign-workers 1 \
    --format json --out "$TMP/w1.json"
echo "== campaign-smoke: parallel run (4 workers) =="
"$BIN" campaign --spec "$TMP/spec.json" --campaign-workers 4 \
    --format json --out "$TMP/w4.json"

if ! command -v python3 >/dev/null 2>&1; then
    echo "python3 unavailable; falling back to cmp on raw reports"
    cmp "$TMP/w1.json" "$TMP/w4.json" || true
    exit 0
fi

echo "== campaign-smoke: cross-worker determinism + sharing invariants =="
python3 - "$TMP/w1.json" "$TMP/w4.json" <<'EOF'
import json
import sys

a, b = (json.load(open(p)) for p in sys.argv[1:3])
for doc in (a, b):
    assert doc["command"] == "campaign", doc.get("command")
    assert doc["num_cells"] == 6, doc["num_cells"]
    # wall_ms is the single nondeterministic report field
    doc.pop("wall_ms", None)
assert a == b, "campaign report differs between 1 and 4 workers"
print("  report identical at 1 and 4 workers (wall_ms stripped): OK")

sharing = a["cache_sharing"]
assert len(sharing) == 1 and sharing[0]["model"] == "synthetic-L6", sharing
m = sharing[0]
assert 0 < m["private_misses"] <= m["requests"], m
assert 0 < m["unique_keys"] <= m["private_misses"], m
assert m["saved_backend_evals"] == m["private_misses"] - m["unique_keys"], m
assert a["total_backend_evals"] == m["private_misses"], (
    "single-model campaign: total_backend_evals must equal its private misses"
)
print(
    "  cache_sharing: {requests} requests, {private_misses} misses, "
    "{unique_keys} unique keys, {saved_backend_evals} saved".format(**m)
)
EOF
echo "campaign-smoke: OK"
