#!/usr/bin/env bash
# Repo gate: format, build, tests, smoke runs, and the perf sections
# with a monotonicity check on BENCH_eval_engine.json (ROADMAP: keep the
# 1/2/4-thread trajectory monotone), the telemetry disabled-path
# overhead gate on BENCH_telemetry_overhead.json (<2%), the
# campaign-scheduler throughput gate on BENCH_campaign.json (cells/s at
# 4 workers must not fall below serial), the NSGA-II selection
# pipeline gate on BENCH_variation.json (pop-1024 wall monotone over
# selection_threads 1/2/4 + both determinism contracts), and the
# offline trace-analyzer throughput gate on BENCH_trace_analyze.json
# (>= 50k events/s, deterministic report). Run via `make check`.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check (format gate) =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "rustfmt unavailable; skipping format gate"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# NSGA-II selection pipeline: exercise both determinism regimes. The
# env override reaches Nsga2Config through the spec precedence chain
# (defaults < file < env < CLI), so =1 pins the legacy bitwise serial
# path and =4 pins the seed-deterministic parallel path.
echo "== nsga2 tests, selection_threads forced to 1 and 4 =="
AFARE_SELECTION_THREADS=1 cargo test -q --lib nsga2
AFARE_SELECTION_THREADS=4 cargo test -q --lib nsga2
AFARE_SELECTION_THREADS=1 cargo test -q --test nsga2_parallel
AFARE_SELECTION_THREADS=4 cargo test -q --test nsga2_parallel

echo "== clippy (lint gate) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --release --all-targets -- -D warnings
else
    echo "clippy unavailable; skipping lint gate"
fi

echo "== chaos smoke (resilient serving determinism) =="
bash scripts/chaos_smoke.sh

echo "== trace smoke (JSONL trace schema + determinism) =="
bash scripts/trace_smoke.sh

echo "== campaign smoke (parallel scheduler cross-worker determinism) =="
bash scripts/campaign_smoke.sh

echo "== bench_perf (eval-engine section, fast budgets) =="
AFARE_BENCH_FAST=1 cargo bench --bench bench_perf

echo "== BENCH_telemetry_overhead.json disabled-path gate =="
if command -v python3 >/dev/null 2>&1; then
python3 - <<'EOF'
import json
import sys

with open("BENCH_telemetry_overhead.json") as f:
    doc = json.load(f)

pct = doc["disabled_overhead_pct"]
threshold = doc["threshold_pct"]
print(
    f"  disabled path: {doc['ns_per_disabled_call']:.1f} ns/call x "
    f"{doc['telemetry_ops_per_run']:.0f} calls/run = {pct:.4f}% "
    f"(enabled delta {doc['enabled_overhead_pct']:+.2f}%)"
)
if not doc.get("pass", False) or pct >= threshold:
    sys.exit(f"telemetry disabled-path overhead {pct:.4f}% >= {threshold}%")
print("  telemetry overhead gate: OK")
EOF
else
    echo "python3 unavailable; skipping telemetry overhead gate"
fi

echo "== BENCH_campaign.json scheduler throughput gate =="
if command -v python3 >/dev/null 2>&1; then
python3 - <<'EOF'
import json
import sys

with open("BENCH_campaign.json") as f:
    doc = json.load(f)

rows = sorted(doc["workers"], key=lambda r: r["workers"])
if len(rows) < 2:
    sys.exit("campaign bench recorded fewer than 2 worker counts")
for r in rows:
    print(f"  {r['workers']}w: {r['wall_ms']:.1f} ms  {r['cells_per_s']:.1f} cells/s")
speedup = doc.get("speedup_4w_vs_1w", 0.0)
print(f"  speedup {rows[-1]['workers']}w vs serial: {speedup:.2f}x")
ok = True
# cells/s at the top worker count must not fall below serial
if rows[-1]["cells_per_s"] < rows[0]["cells_per_s"]:
    ok = False
    print("NON-MONOTONE: parallel campaign slower than serial")
if not doc.get("deterministic_across_workers", False):
    ok = False
    print("DETERMINISM flag missing from campaign bench output")
sys.exit(0 if ok else "campaign scheduler throughput regressed")
EOF
else
    echo "python3 unavailable; skipping campaign throughput gate"
fi

echo "== BENCH_eval_engine.json monotonicity =="
if ! command -v python3 >/dev/null 2>&1; then
    echo "python3 unavailable; skipping monotonicity check"
    exit 0
fi
python3 - <<'EOF'
import json
import sys

with open("BENCH_eval_engine.json") as f:
    doc = json.load(f)

rows = sorted(doc["threads"], key=lambda r: r["threads"])
if len(rows) < 2:
    sys.exit("eval-engine bench recorded fewer than 2 thread counts")

# Wall-clock must not regress as threads grow (10% timing-noise slack).
SLACK = 1.10
ok = True
for lo, hi in zip(rows, rows[1:]):
    if hi["wall_ms"] > lo["wall_ms"] * SLACK:
        ok = False
        print(
            f"NON-MONOTONE: {hi['threads']}T wall {hi['wall_ms']:.1f} ms vs "
            f"{lo['threads']}T {lo['wall_ms']:.1f} ms (> {SLACK:.0%})"
        )
for r in rows:
    print(f"  {r['threads']}T: {r['wall_ms']:.1f} ms  {r['evals_per_s']:.0f} evals/s")

speedup = doc.get("speedup_4t_vs_1t", 0.0)
print(f"  speedup {rows[-1]['threads']}T vs 1T: {speedup:.2f}x")
if speedup < 1.0:
    ok = False
    print("NON-MONOTONE: top thread count slower than serial")
if not doc.get("deterministic_across_threads", False):
    ok = False
    print("DETERMINISM flag missing from bench output")

sys.exit(0 if ok else "eval-engine perf trajectory regressed")
EOF

echo "== BENCH_variation.json selection-pipeline gate =="
python3 - <<'EOF'
import json
import sys

with open("BENCH_variation.json") as f:
    doc = json.load(f)

rows = [r for r in doc["pops"] if r["pop_size"] == 1024]
rows.sort(key=lambda r: r["selection_threads"])
if len(rows) < 2:
    sys.exit("variation bench recorded fewer than 2 thread counts at pop 1024")

# Wall-clock at pop 1024 must not regress as selection_threads grows
# (10% timing-noise slack, same policy as the eval-engine gate).
SLACK = 1.10
ok = True
for lo, hi in zip(rows, rows[1:]):
    if hi["wall_ms"] > lo["wall_ms"] * SLACK:
        ok = False
        print(
            f"NON-MONOTONE: sel={hi['selection_threads']} wall "
            f"{hi['wall_ms']:.1f} ms vs sel={lo['selection_threads']} "
            f"{lo['wall_ms']:.1f} ms (> {SLACK:.0%})"
        )
for r in rows:
    print(
        f"  sel={r['selection_threads']}: {r['wall_ms']:.1f} ms  "
        f"{r['offspring_per_s']:.0f} offspring/s  "
        f"({r['speedup_vs_1t']:.2f}x vs 1t)"
    )
if rows[-1]["speedup_vs_1t"] < 1.0:
    ok = False
    print("NON-MONOTONE: top selection_threads slower than serial")
if not doc.get("serial_bitwise_identical", False):
    ok = False
    print("LEGACY CONTRACT flag missing: serial path vs pre-PR oracle")
if not doc.get("forked_deterministic", False):
    ok = False
    print("FORKED CONTRACT flag missing: parallel path not thread-invariant")

sys.exit(0 if ok else "NSGA-II selection pipeline gate failed")
EOF

echo "== BENCH_trace_analyze.json analyzer throughput gate =="
python3 - <<'EOF'
import json
import sys

with open("BENCH_trace_analyze.json") as f:
    doc = json.load(f)

eps = doc["events_per_sec"]
print(
    f"  {doc['events']:.0f} events ({doc['bytes'] / 2**20:.1f} MiB): "
    f"{doc['min_ms']:.1f} ms min -> {eps:.0f} events/s"
)
ok = True
# Post-processing must stay comfortably faster than emission: a 120-tick
# chaos run produces a few hundred events, so anything above 50k
# events/s keeps `trace analyze` invisible next to the run itself.
if eps < 50_000:
    ok = False
    print(f"SLOW: analyzer at {eps:.0f} events/s (< 50k floor)")
if not doc.get("deterministic", False):
    ok = False
    print("DETERMINISM flag missing from trace-analyze bench output")
sys.exit(0 if ok else "trace analyzer throughput gate failed")
EOF
echo "check: OK"
