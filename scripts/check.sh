#!/usr/bin/env bash
# Repo gate: build, tests, and the eval-engine perf section with a
# monotonicity check on BENCH_eval_engine.json (ROADMAP: keep the
# 1/2/4-thread trajectory monotone). Run via `make check`.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== clippy (lint gate) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --release --all-targets -- -D warnings
else
    echo "clippy unavailable; skipping lint gate"
fi

echo "== chaos smoke (resilient serving determinism) =="
bash scripts/chaos_smoke.sh

echo "== bench_perf (eval-engine section, fast budgets) =="
AFARE_BENCH_FAST=1 cargo bench --bench bench_perf

echo "== BENCH_eval_engine.json monotonicity =="
if ! command -v python3 >/dev/null 2>&1; then
    echo "python3 unavailable; skipping monotonicity check"
    exit 0
fi
python3 - <<'EOF'
import json
import sys

with open("BENCH_eval_engine.json") as f:
    doc = json.load(f)

rows = sorted(doc["threads"], key=lambda r: r["threads"])
if len(rows) < 2:
    sys.exit("eval-engine bench recorded fewer than 2 thread counts")

# Wall-clock must not regress as threads grow (10% timing-noise slack).
SLACK = 1.10
ok = True
for lo, hi in zip(rows, rows[1:]):
    if hi["wall_ms"] > lo["wall_ms"] * SLACK:
        ok = False
        print(
            f"NON-MONOTONE: {hi['threads']}T wall {hi['wall_ms']:.1f} ms vs "
            f"{lo['threads']}T {lo['wall_ms']:.1f} ms (> {SLACK:.0%})"
        )
for r in rows:
    print(f"  {r['threads']}T: {r['wall_ms']:.1f} ms  {r['evals_per_s']:.0f} evals/s")

speedup = doc.get("speedup_4t_vs_1t", 0.0)
print(f"  speedup {rows[-1]['threads']}T vs 1T: {speedup:.2f}x")
if speedup < 1.0:
    ok = False
    print("NON-MONOTONE: top thread count slower than serial")
if not doc.get("deterministic_across_threads", False):
    ok = False
    print("DETERMINISM flag missing from bench output")

sys.exit(0 if ok else "eval-engine perf trajectory regressed")
EOF
echo "check: OK"
