//! Strategy comparison + Pareto exploration: run CNNParted, the
//! fault-unaware baseline, greedy, random search and AFarePart on one
//! model/scenario and dump a CSV of the AFarePart front for plotting.
//!
//!     cargo run --release --example pareto_explore [model] [> front.csv]

use anyhow::Result;

use afarepart::baselines::{
    greedy_latency_mapping, random_search_mapping, CnnParted, FaultUnaware,
};
use afarepart::coordinator::OfflineRunner;
use afarepart::experiment::Experiment;
use afarepart::faults::FaultScenario;
use afarepart::partition::Mapping;
use afarepart::util::fmt::{pct, Table};

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "squeezenet".into());
    let exp = Experiment::builder()
        .model(&model)
        .fault_rate(0.2)
        .scenario(FaultScenario::InputWeight)
        .eval_limit(128)
        .pop(24)
        .gens(12)
        .build()?;
    let cfg = exp.config().clone();
    println!(
        "# strategy comparison: {} at FR={} ({})",
        cfg.model,
        cfg.fault_rate,
        cfg.scenario.label()
    );

    let mut rows: Vec<(&str, Mapping)> = Vec::new();

    let mut ev = exp.partition_evaluator(cfg.scenario);
    rows.push(("CNNParted", CnnParted::new(cfg.nsga2.clone()).partition(&mut ev)?));

    let mut ev2 = exp.partition_evaluator(cfg.scenario);
    rows.push(("Flt-unaware", FaultUnaware::new(cfg.nsga2.clone()).partition(&mut ev2)?));

    let ev3 = exp.partition_evaluator(cfg.scenario);
    rows.push(("Greedy", greedy_latency_mapping(&ev3, 0.5)));

    let mut ev4 = exp.partition_evaluator(cfg.scenario);
    rows.push((
        "RandomSearch",
        random_search_mapping(&mut ev4, 64, (1.0, 10.0, 100.0), cfg.seed)?,
    ));

    let mut ev5 = exp.partition_evaluator(cfg.scenario);
    let runner = OfflineRunner { nsga2: cfg.nsga2.clone(), ..Default::default() };
    let out = runner.run(&mut ev5, vec![], |_| {})?;
    rows.push(("AFarePart", out.deployed.clone()));

    let mut scorer = exp.partition_evaluator(cfg.scenario);
    let mut t = Table::new(&["strategy", "mapping", "faulty acc", "dAcc", "lat ms", "energy mJ"]);
    for (name, m) in &rows {
        let acc = scorer.faulty_accuracy(m)?;
        t.row(vec![
            name.to_string(),
            m.display(),
            pct(acc),
            pct((exp.clean_acc - acc).max(0.0)),
            format!("{:.2}", scorer.latency_ms(m)),
            format!("{:.3}", scorer.energy_mj(m)),
        ]);
    }
    print!("{}", t.render());

    println!("\n# AFarePart Pareto front (CSV):");
    println!("mapping,latency_ms,energy_mj,dacc");
    for ind in &out.front {
        println!(
            "{},{:.4},{:.5},{:.4}",
            Mapping(ind.genome.clone()).display(),
            ind.objectives[0],
            ind.objectives[1],
            ind.objectives[2]
        );
    }
    Ok(())
}
