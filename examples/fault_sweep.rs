//! Layer-wise fault sweeping (paper §V-C methodology): inject faults into
//! one unit at a time across a rate grid, in both domains, and print the
//! per-layer sensitivity profile — the data behind the surrogate mode and
//! the intuition for why partition choice changes resilience.
//!
//!     cargo run --release --example fault_sweep [model]

use anyhow::Result;

use afarepart::experiment::Experiment;
use afarepart::faults::RateVectors;
use afarepart::util::fmt::{pct, Table};

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "resnet18".into());
    let exp = Experiment::builder().model(&model).eval_limit(128).build()?;
    let cfg = exp.config().clone();
    let grid = [0.1f32, 0.2, 0.3, 0.4];
    println!(
        "layer-wise fault sweep: {} — clean quantized top-1 {}\n(accuracy DROP per unit; w = weight faults, a = activation faults)",
        cfg.model,
        pct(exp.clean_acc)
    );

    let l = exp.model.num_units();
    let mut t = Table::new(&["unit", "kind", "FR=.1 w/a", "FR=.2 w/a", "FR=.3 w/a", "FR=.4 w/a"]);
    let mut most_sensitive = (0usize, 0.0f64);
    for unit in 0..l {
        let uc = &exp.model.manifest.units[unit];
        let mut cells = vec![uc.name.clone(), uc.kind.clone()];
        for &r in &grid {
            let mut rv = RateVectors::zeros(l);
            rv.w_rates[unit] = r;
            let dw = (exp.clean_acc - exp.acc_eval.accuracy(&exp.model, &rv, 1, 0)?).max(0.0);
            let mut rv = RateVectors::zeros(l);
            rv.a_rates[unit] = r;
            let da = (exp.clean_acc - exp.acc_eval.accuracy(&exp.model, &rv, 1, 0)?).max(0.0);
            if r == 0.4 && dw + da > most_sensitive.1 {
                most_sensitive = (unit, dw + da);
            }
            cells.push(format!("{}/{}", pct(dw), pct(da)));
        }
        t.row(cells);
    }
    print!("{}", t.render());
    println!(
        "\nmost sensitive unit at FR=0.4: {} — AFarePart will fight to keep it on the shielded device",
        exp.model.manifest.units[most_sensitive.0].name
    );
    Ok(())
}
