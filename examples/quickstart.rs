//! Quickstart: the 60-second tour of the AFarePart public API.
//!
//! Builds an experiment with the declarative builder (model, fault
//! environment, optimizer budget in one fluent chain), runs a small
//! offline optimization with the paper's three objectives, and prints
//! the Pareto front + deployed P*.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use afarepart::coordinator::OfflineRunner;
use afarepart::experiment::Experiment;
use afarepart::faults::FaultScenario;
use afarepart::partition::Mapping;
use afarepart::util::fmt::pct;

fn main() -> Result<()> {
    // 1. Describe the experiment declaratively and load it. The builder
    //    is a thin veneer over `spec::ExperimentSpec` — everything here
    //    (and much more: platform topology, drift schedules, selection
    //    policy) can equally come from one JSON file via
    //    `ExperimentSpec::from_file` + `Experiment::from_spec`.
    //    See docs/spec.md for the schema.
    let exp = Experiment::builder()
        .model("alexnet")
        .fault_rate(0.2)                      // 20% per-bit flip probability
        .scenario(FaultScenario::InputWeight) // faults in both domains
        .eval_limit(64)                       // accuracy eval subset
        .pop(24)
        .gens(10)
        .build()?; // compiles the AOT HLO once, loads weights + eval set
    println!(
        "loaded {} (clean quantized top-1 = {})",
        exp.model.manifest.model,
        pct(exp.clean_acc)
    );
    let cfg = exp.config().clone();

    // 2. Offline phase (paper Algorithm 1, lines 1-12): evolve mappings.
    let mut evaluator = exp.partition_evaluator(cfg.scenario);
    let runner = OfflineRunner { nsga2: cfg.nsga2.clone(), ..Default::default() };
    let outcome = runner.run(&mut evaluator, vec![], |gs| {
        println!(
            "  gen {:2}: best dAcc so far = {}",
            gs.generation,
            pct(gs.best_per_objective[2])
        );
    })?;

    // 3. Inspect the Pareto front and the deployed mapping.
    println!("\nPareto front ({} partitions):", outcome.front.len());
    for ind in &outcome.front {
        println!(
            "  {}  lat {:6.2} ms  energy {:6.3} mJ  dAcc {}",
            Mapping(ind.genome.clone()).display(),
            ind.objectives[0],
            ind.objectives[1],
            pct(ind.objectives[2]),
        );
    }
    println!(
        "\ndeployed P* = {} (device per unit, 0=eyeriss 1=simba)",
        outcome.deployed.display()
    );

    // 4. Compare against the naive all-on-one-device mappings.
    let n = exp.model.num_units();
    for (name, m) in [("all-eyeriss", Mapping::all_on(0, n)), ("all-simba", Mapping::all_on(1, n))] {
        let acc = evaluator.faulty_accuracy(&m)?;
        println!(
            "  {name:12} lat {:6.2} ms  energy {:6.3} mJ  faulty acc {}",
            evaluator.latency_ms(&m),
            evaluator.energy_mj(&m),
            pct(acc),
        );
    }
    Ok(())
}
