//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): the full AFarePart system on a
//! live workload — offline optimization, real PJRT serving through the
//! threaded inference server, a drifting fault environment (EM step attack
//! on the edge accelerator at t=40s), the rolling accuracy monitor, and
//! θ-triggered dynamic repartitioning (paper Algorithm 1, both phases).
//!
//! Expected behaviour: accuracy collapses when the attack starts, the
//! monitor crosses θ, the coordinator re-runs NSGA-II with current rates
//! and swaps in a mapping that moves sensitive units off the attacked
//! device, and accuracy recovers — all without python in the loop.
//!
//!     make artifacts && cargo run --release --example online_reconfig

use anyhow::Result;

use afarepart::coordinator::server::InferenceServer;
use afarepart::coordinator::{OfflineRunner, OnlineConfig, OnlineRunner};
use afarepart::experiment::Experiment;
use afarepart::faults::{ChaosEngine, DriftComponent, FaultEnv, FaultScenario};
use afarepart::model::Manifest;
use afarepart::obs::Telemetry;
use afarepart::util::fmt::pct;

fn main() -> Result<()> {
    let exp = Experiment::builder()
        .model(&std::env::args().nth(1).unwrap_or_else(|| "alexnet".into()))
        .fault_rate(0.12) // ambient FR; the attack doubles it on dev0
        .scenario(FaultScenario::InputWeight)
        .eval_limit(128)
        .pop(24)
        .gens(10)
        .theta(0.05)
        // drifting environment: EM step attack on dev0 at t = 40 s
        .drift(vec![DriftComponent::step(0, 40.0, 2.5)])
        .build()?;
    let cfg = exp.config().clone();
    println!(
        "[e2e] {} loaded; clean quantized top-1 = {}",
        cfg.model,
        pct(exp.clean_acc)
    );

    // --- offline phase: initial P* under the ambient environment.
    // Accuracy-first budgets: robustness costs ~2-3x energy on this
    // platform, and the demo's story is resilience under attack.
    let mut offline_ev = exp.partition_evaluator(cfg.scenario);
    let runner = OfflineRunner {
        nsga2: cfg.nsga2.clone(),
        lat_budget: 2.5,
        energy_budget: 4.0,
    };
    let initial = runner.run(&mut offline_ev, vec![], |_| {})?.deployed;
    println!("[e2e] offline P* = {}", initial.display());

    // --- spawn the serving thread (owns its own PJRT client + executable)
    let manifest = Manifest::load(&exp.index.manifest_path(&cfg.model))?;
    let server = InferenceServer::spawn(
        cfg.artifacts_dir.clone(),
        manifest,
        (exp.eval_set.h, exp.eval_set.w, exp.eval_set.c),
    )?;
    println!("[e2e] inference server up (batch {})", server.batch);

    // --- the drifting environment declared on the builder above (the
    // drift stack is composable: push more components for step+sinusoid
    // scenarios)
    let env: FaultEnv = exp.fault_env();

    // Exact-mode re-optimization: the per-unit sensitivity surrogate
    // cannot capture cross-layer fault *accumulation* (single-unit drops
    // compose to ~0 while the combined drop is large — see
    // bench_ablation A1), so the online coordinator pays for real
    // fault-injected evaluations; the dAcc memo cache keeps each re-opt
    // to a few dozen PJRT executions.
    let mut reopt_ev = exp.partition_evaluator(cfg.scenario);

    let online_cfg = OnlineConfig {
        theta: cfg.theta,
        ticks: 120,
        window: 8,
        tick_seconds: 1.0,
        cooldown: 10,
        ..Default::default()
    };
    let mut online = OnlineRunner {
        cfg: online_cfg,
        server: &server,
        evaluator: &mut reopt_ev,
        clean_acc: exp.clean_acc,
        // the demo exercises drift + repartitioning only; serving-failure
        // injection and degradation are `afarepart online --chaos` territory
        chaos: ChaosEngine::disabled(),
        safe_mapping: None,
        telemetry: Telemetry::disabled(),
    };

    println!("[e2e] serving 120 ticks; attack begins at t=40s; θ = {}", pct(cfg.theta));
    let out = online.run(&exp.eval_set, &env, initial, |p| {
        if p.tick % 8 == 0 || p.reconfigured {
            println!(
                "  t={:5.1}s  FR(dev0)={:.2}  batch acc={}  rolling={}  P={} {}",
                p.sim_time_s,
                p.env_rate_dev0,
                pct(p.batch_accuracy),
                pct(p.rolling_accuracy),
                p.mapping.display(),
                if p.reconfigured { "<-- REPARTITIONED" } else { "" }
            );
        }
    })?;

    // --- headline numbers
    let pre_attack: Vec<f64> = out
        .timeline
        .iter()
        .filter(|p| p.sim_time_s < 40.0)
        .map(|p| p.batch_accuracy)
        .collect();
    let post_attack_pre_fix: Vec<f64> = out
        .timeline
        .iter()
        .filter(|p| p.sim_time_s >= 40.0 && !p.reconfigured && p.mapping == out.timeline[0].mapping)
        .map(|p| p.batch_accuracy)
        .collect();
    let tail: Vec<f64> = out
        .timeline
        .iter()
        .rev()
        .take(20)
        .map(|p| p.batch_accuracy)
        .collect();
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    println!("\n[e2e] === outcome ===");
    println!("  pre-attack accuracy      : {}", pct(mean(&pre_attack)));
    if !post_attack_pre_fix.is_empty() {
        println!("  under attack (old P*)    : {}", pct(mean(&post_attack_pre_fix)));
    }
    println!("  final 20 ticks (post-fix): {}", pct(mean(&tail)));
    println!(
        "  reconfigurations: {}  final P = {}",
        out.metrics.reconfigurations,
        out.final_mapping.display()
    );
    if let Some(s) = out.metrics.exec_summary() {
        println!("  PJRT exec: mean {:.1} ms  p95 {:.1} ms  ({} batches)", s.mean, s.p95, s.n);
    }
    if let Some(s) = out.metrics.reopt_summary() {
        println!("  re-optimization wall time: mean {:.0} ms", s.mean);
    }
    Ok(())
}
